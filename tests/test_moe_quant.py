"""Property harness for quantized expert tiles + router lookahead
(DESIGN.md §7).

Covers the quantized storage format (round-trip error bounds, int4
pack/unpack), the in-kernel-dequant fused decode and ragged gmm kernels in
interpret mode against the numpy/f64 dequant oracle, the jnp
dequant-after-gather fallbacks, the lookahead hit-select no-op, and the
serving contracts: quantize-at-load, greedy-token match + ppl pin on a
trained model under a heterogeneous LExI plan, spec-key separation of
bf16/int8 engines, and the bf16-only guard on the capacity/EP impls.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import models
from repro.configs import get_config
from repro.core import iter_moe_layer_params
from repro.kernels import ops, ref
from repro.kernels.moe_decode import (
    moe_decode_quant_pallas,
    moe_decode_routed_jnp,
    moe_decode_routed_quant_jnp,
)
from repro.models.moe import (
    QUANT_DTYPES,
    dequantize_experts,
    moe,
    moe_decode,
    moe_gmm,
    quantize_expert_params,
    quantize_experts,
    quantize_moe_layer,
    route,
    route_lookahead,
    unpack_int4,
)
from repro.models.moe import params as moe_params

TOL = dict(rtol=3e-5, atol=3e-5)


def _random_case(seed, b, e, k, d=32, f=48):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, d)).astype(np.float32)
    w1 = (rng.normal(size=(e, d, 2 * f)) * 0.05).astype(np.float32)
    w2 = (rng.normal(size=(e, f, d)) * 0.05).astype(np.float32)
    idx = rng.integers(0, e, size=(b, k)).astype(np.int32)
    w = rng.random((b, k)).astype(np.float32)
    return x, w1, w2, idx, w


def _quant_case(seed, b, e, k, dtype, d=32, f=48):
    x, w1, w2, idx, w = _random_case(seed, b, e, k, d=d, f=f)
    w1q, w2q, s1, s2 = quantize_experts(jnp.asarray(w1), jnp.asarray(w2),
                                        dtype)
    return (jnp.asarray(x), w1q, w2q, s1, s2, jnp.asarray(idx),
            jnp.asarray(w))


def _np_case(case):
    return tuple(np.asarray(a) for a in case)


# --------------------------------------------------------------------------- #
# Storage format
# --------------------------------------------------------------------------- #


class TestQuantFormat:
    def test_int4_pack_unpack_roundtrip(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.integers(-8, 8, size=(4, 10, 5)).astype(np.int8))
        for axis in (0, 1):
            packed = moe_params._pack_int4(q, axis=axis)
            assert packed.shape[axis] == q.shape[axis] // 2
            assert packed.dtype == jnp.int8
            out = unpack_int4(packed, axis=axis)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(q))

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_roundtrip_error_bound(self, dtype):
        """Symmetric absmax quantization: every element reconstructs within
        half a quantization step of its channel's scale."""
        _, w1, w2, _, _ = _random_case(1, 1, 6, 1)
        w1q, w2q, s1, s2 = quantize_experts(jnp.asarray(w1),
                                            jnp.asarray(w2), dtype)
        dw1, dw2 = dequantize_experts(w1q, w2q, s1, s2, dtype)
        e, d, twof = w1.shape
        f = twof // 2
        err1 = np.abs(np.asarray(dw1) - w1).reshape(e, d, 2, f)
        bound1 = 0.5 * np.asarray(s1)[:, None] + 1e-6
        assert (err1 <= bound1).all()
        err2 = np.abs(np.asarray(dw2) - w2)
        bound2 = 0.5 * np.asarray(s2)[..., None] + 1e-6
        assert (err2 <= bound2).all()
        # channel extrema (the absmax elements) land exactly on +-qmax
        assert float(np.max(np.abs(np.asarray(dw1) - w1))) < float(
            np.max(np.asarray(s1)))

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_int4_packs_contraction_dim(self, dtype):
        _, w1, w2, _, _ = _random_case(2, 1, 4, 1, d=32, f=48)
        w1q, w2q, s1, s2 = quantize_experts(jnp.asarray(w1),
                                            jnp.asarray(w2), dtype)
        dp = 16 if dtype == "int4" else 32
        assert w1q.shape == (4, dp, 96) and w1q.dtype == jnp.int8
        assert w2q.shape == (4, 48, dp) and w2q.dtype == jnp.int8
        assert s1.shape == (4, 2, 48) and s1.dtype == jnp.float32
        assert s2.shape == (4, 48) and s2.dtype == jnp.float32

    def test_rejects_bad_dtype_and_double_quantize(self):
        _, w1, w2, _, _ = _random_case(3, 1, 2, 1)
        with pytest.raises(ValueError, match="not in"):
            quantize_experts(jnp.asarray(w1), jnp.asarray(w2), "fp8")
        p = {"w1": jnp.asarray(w1), "w2": jnp.asarray(w2)}
        qp = quantize_moe_layer(p, "int8")
        with pytest.raises(ValueError, match="already quantized"):
            quantize_moe_layer(qp, "int8")

    def test_quantize_expert_params_shares_non_expert_leaves(self):
        cfg = get_config("olmoe-1b-7b").reduced().with_(
            num_layers=2, dtype="float32")
        params = models.init_params(jax.random.PRNGKey(0), cfg)
        qparams = quantize_expert_params(params, cfg, "int8")
        # non-expert leaves are the same arrays, not copies
        assert qparams["embed"] is params["embed"]
        g0 = params["stack"]["groups"][0]
        q0 = qparams["stack"]["groups"][0]
        assert q0["attn"] is g0["attn"]
        assert q0["moe"]["router"] is g0["moe"]["router"]
        assert q0["moe"]["w1"].dtype == jnp.int8
        assert "w1_scale" in q0["moe"] and "w1_scale" not in g0["moe"]


# --------------------------------------------------------------------------- #
# Kernel vs f64 dequant oracle (interpret mode: kernel body runs on CPU)
# --------------------------------------------------------------------------- #


def _quant_kernel(case, dtype, **kw):
    return np.asarray(moe_decode_quant_pallas(*case, dtype=dtype,
                                              interpret=True, **kw))


class TestQuantKernelVsOracle:
    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    @pytest.mark.parametrize("b,e,k", [
        (1, 8, 2),      # B=1: the single-sequence decode step
        (8, 4, 4),      # k == E: every expert routed by every token
        (7, 5, 3),      # nothing power-of-two
    ])
    def test_matches_f64_dequant_oracle(self, dtype, b, e, k):
        case = _quant_case(b * 31 + e + k, b, e, k, dtype)
        exp = ref.moe_decode_quant_ref(*_np_case(case), dtype=dtype)
        out = _quant_kernel(case, dtype, block_f=16)   # multi f-step accum
        np.testing.assert_allclose(out, exp, **TOL)
        fb = np.asarray(moe_decode_routed_quant_jnp(*case, dtype=dtype))
        np.testing.assert_allclose(fb, exp, **TOL)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 8),
           st.sampled_from(QUANT_DTYPES), st.sampled_from((16, 48)),
           st.integers(0, 10_000))
    def test_property_fuzz(self, b, e, k, dtype, block_f, seed):
        k = min(k, e)
        case = _quant_case(seed, b, e, k, dtype)
        exp = ref.moe_decode_quant_ref(*_np_case(case), dtype=dtype)
        np.testing.assert_allclose(_quant_kernel(case, dtype,
                                                 block_f=block_f),
                                   exp, **TOL)
        np.testing.assert_allclose(
            np.asarray(moe_decode_routed_quant_jnp(*case, dtype=dtype)),
            exp, **TOL)

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_duplicate_expert_ids_accumulate(self, dtype):
        x, w1q, w2q, s1, s2, _, w = _quant_case(3, 2, 4, 2, dtype)
        idx = jnp.asarray([[1, 1], [3, 3]], jnp.int32)
        case = (x, w1q, w2q, s1, s2, idx, w)
        exp = ref.moe_decode_quant_ref(*_np_case(case), dtype=dtype)
        np.testing.assert_allclose(_quant_kernel(case, dtype), exp, **TOL)

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_ops_wrapper_matches_kernel(self, dtype):
        """ops.moe_decode_quant (the jnp path the engine runs off-TPU) and
        the interpret-mode kernel body agree."""
        case = _quant_case(11, 6, 8, 3, dtype)
        fb = np.asarray(ops.moe_decode_quant(*case, dtype=dtype))
        np.testing.assert_allclose(_quant_kernel(case, dtype, block_f=16),
                                   fb, **TOL)

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_quant_tracks_full_precision(self, dtype):
        """Quantized output == full-precision output on the *dequantized*
        weights (the only error quantization adds is in the weights)."""
        case = _quant_case(17, 4, 6, 2, dtype)
        x, w1q, w2q, s1, s2, idx, w = case
        dw1, dw2 = dequantize_experts(w1q, w2q, s1, s2, dtype)
        y_fp = np.asarray(moe_decode_routed_jnp(x, dw1, dw2, idx, w))
        y_q = np.asarray(moe_decode_routed_quant_jnp(*case, dtype=dtype))
        np.testing.assert_allclose(y_q, y_fp, **TOL)


# --------------------------------------------------------------------------- #
# Impl-level: quantized decode == quantized gmm (kernel and jnp paths)
# --------------------------------------------------------------------------- #


def _layer(e, k, *, shared=False, seed=0):
    cfg = get_config("olmoe-1b-7b").reduced().with_(
        num_experts=e, moe_top_k=k, dtype="float32",
        moe_capacity_factor=float(e),
        num_shared_experts=1 if shared else 0,
        shared_expert_d_ff=32 if shared else 0)
    params = models.init_params(jax.random.PRNGKey(seed), cfg)
    _, mp = next(iter_moe_layer_params(params, cfg))
    return cfg, mp


class TestQuantImplEquivalence:
    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    @pytest.mark.parametrize("e,k,t,shared", [
        (8, 2, 1, False),
        (8, 8, 4, False),    # k == E
        (4, 2, 7, True),     # shared expert stays full precision
    ])
    def test_decode_matches_gmm_quant(self, dtype, e, k, t, shared):
        cfg, mp = _layer(e, k, shared=shared)
        qmp = quantize_moe_layer(mp, dtype)
        x = jax.random.normal(jax.random.PRNGKey(t), (t, cfg.d_model))
        y_dec, _ = moe_decode(qmp, cfg, x, k, expert_dtype=dtype)
        y_dk, _ = moe_decode(qmp, cfg, x, k, use_kernel=True,
                             expert_dtype=dtype)
        y_gmm, _ = moe_gmm(qmp, cfg, x, k, expert_dtype=dtype)
        y_gk, _ = moe_gmm(qmp, cfg, x, k, use_kernel=True,
                          expert_dtype=dtype)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_gmm),
                                   **TOL)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_dk),
                                   **TOL)
        np.testing.assert_allclose(np.asarray(y_gmm), np.asarray(y_gk),
                                   **TOL)

    def test_unquantized_params_give_clear_error(self):
        cfg, mp = _layer(4, 2)
        x = jax.random.normal(jax.random.PRNGKey(0), (2, cfg.d_model))
        with pytest.raises(ValueError, match="quantize_expert_params"):
            moe_decode(mp, cfg, x, 2, expert_dtype="int8")

    def test_registry_guards_bf16_only_impls(self):
        cfg, mp = _layer(4, 2)
        qmp = quantize_moe_layer(mp, "int8")
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, cfg.d_model))
        with pytest.raises(ValueError, match="gmm.*decode|decode.*gmm"):
            moe(qmp, cfg, x, 2, impl="dense", expert_dtype="int8")
        # gmm and decode serve it
        y0, _ = moe(qmp, cfg, x, 2, impl="gmm", expert_dtype="int8")
        y1, _ = moe(qmp, cfg, x, 2, impl="decode", expert_dtype="int8")
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), **TOL)


# --------------------------------------------------------------------------- #
# Router lookahead: a numeric no-op that reorders dependencies
# --------------------------------------------------------------------------- #


class TestLookahead:
    def test_pred_idx_is_exact_noop_bf16(self):
        x, w1, w2, idx, w = map(jnp.asarray, _random_case(5, 6, 8, 3))
        pred = jax.random.randint(jax.random.PRNGKey(0), idx.shape, 0, 8)
        y0 = moe_decode_routed_jnp(x, w1, w2, idx, w)
        y1 = moe_decode_routed_jnp(x, w1, w2, idx, w, pred.astype(jnp.int32))
        y2 = moe_decode_routed_jnp(x, w1, w2, idx, w, idx)  # all hits
        assert jnp.array_equal(y0, y1) and jnp.array_equal(y0, y2)

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_pred_idx_is_exact_noop_quant(self, dtype):
        case = _quant_case(5, 6, 8, 3, dtype)
        idx = case[5]
        pred = jax.random.randint(jax.random.PRNGKey(1), idx.shape, 0, 8)
        y0 = moe_decode_routed_quant_jnp(*case, dtype=dtype)
        y1 = moe_decode_routed_quant_jnp(*case, dtype=dtype,
                                         pred_idx=pred.astype(jnp.int32))
        assert jnp.array_equal(y0, y1)

    def test_route_lookahead_selects_like_route(self):
        """Given the *true* router input, the lookahead prediction equals
        the ids ``route`` selects (same scoring, same tie-breaking)."""
        cfg, mp = _layer(8, 3)
        x = jax.random.normal(jax.random.PRNGKey(2), (5, cfg.d_model))
        _, idx, _ = route(mp, cfg, x, 3)
        pred = route_lookahead(mp, cfg, x, 3)
        assert pred.dtype == jnp.int32 and pred.shape == idx.shape
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(idx))


# --------------------------------------------------------------------------- #
# Engine-level serving contracts
# --------------------------------------------------------------------------- #


def _moe_plan_cfg():
    cfg = get_config("olmoe-1b-7b").reduced().with_(
        num_layers=4, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        num_experts=8, moe_top_k=4, moe_d_ff=64, vocab_size=128,
        vocab_pad_multiple=16, dtype="float32", moe_impl="gmm")
    return cfg.with_lexi_plan((4, 2, 1, 3))


@pytest.fixture(scope="module")
def trained():
    """Small trained MoE so routing/logits have real structure (the greedy
    match and ppl pin are vacuous on random weights)."""
    from repro.data import DataConfig
    from repro.optim import AdamW
    from repro.training import train
    cfg = get_config("olmoe-1b-7b").reduced().with_(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        num_experts=8, moe_top_k=4, moe_d_ff=128, vocab_size=512,
        vocab_pad_multiple=16, dtype="float32", moe_impl="gmm")
    dc = DataConfig(cfg.vocab_size, seq_len=64, global_batch=16, seed=0)
    res = train(cfg, dc, total_steps=100,
                optimizer=AdamW(peak_lr=2e-3, total_steps=100,
                                warmup_steps=10))
    return cfg, res.state.params, dc


def _serve(cfg, params, plan=None, **engine_kw):
    from repro.serving import Engine, Request
    rng = np.random.default_rng(4)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, n
                                        ).astype(np.int32),
                    max_new_tokens=6)
            for i, n in enumerate((5, 9, 13))]
    eng = Engine(cfg, params, max_batch=3, max_len=64, prefill_chunk=4,
                 **engine_kw)
    if plan is not None:
        eng.add_plan("lexi", plan)
    res = eng.serve(reqs, plan="lexi" if plan is not None else None)
    return eng, [r.tokens for r in res]


class TestEngineQuant:
    def test_int8_greedy_match_and_ppl_pin(self, trained):
        """int8 quantize-at-load under a heterogeneous LExI plan: greedy
        decode must track the bf16 engine almost token-for-token, and
        held-out ppl through the quantized gmm path must stay within
        +0.1 of full precision (the ISSUE's acceptance pin)."""
        from repro.core.apply import apply_plan_params
        from repro.models.opts import ModelOpts
        from repro.training import eval_perplexity
        from repro.core import LexiPlan
        cfg, params, dc = trained
        plan = LexiPlan(arch=cfg.name, budget=10, plan=(4, 2, 1, 3),
                        fitness=0.0, method="uniform", k_base=cfg.moe_top_k)
        _, toks_bf = _serve(cfg, params, plan=plan, use_moe_decode=True)
        _, toks_q = _serve(cfg, params, plan=plan, use_moe_decode=True,
                           expert_dtype="int8")
        match = sum(a == b for s_bf, s_q in zip(toks_bf, toks_q)
                    for a, b in zip(s_bf, s_q))
        total = sum(len(s) for s in toks_bf)
        assert match / total >= 0.9, (toks_bf, toks_q)

        cfg_l, params_l = apply_plan_params(params, cfg, plan)
        ppl_fp = float(eval_perplexity(params_l, cfg_l, dc, steps=4,
                                       opts=ModelOpts(moe_impl="gmm")))
        qp = quantize_expert_params(params_l, cfg_l, "int8")
        ppl_q = float(eval_perplexity(
            qp, cfg_l, dc, steps=4,
            opts=ModelOpts(moe_impl="gmm", expert_dtype="int8")))
        assert ppl_q - ppl_fp <= 0.1, (ppl_fp, ppl_q)

    def test_spec_keys_separate_dtypes(self):
        """bf16 and int8 engines never share a compiled graph: every key
        carries the expert dtype (appended last) and the key sets are
        disjoint."""
        cfg = _moe_plan_cfg()
        params = models.init_params(jax.random.PRNGKey(0), cfg)
        eng_bf, _ = _serve(cfg, params, use_moe_decode=True)
        eng_q, _ = _serve(cfg, params, use_moe_decode=True,
                          expert_dtype="int8")
        keys_bf = eng_bf.runner.compiled_specializations()
        keys_q = eng_q.runner.compiled_specializations()
        assert keys_bf and all(k[-1] == "bf16" for k in keys_bf)
        assert keys_q and all(k[-1] == "int8" for k in keys_q)
        assert not set(keys_bf) & set(keys_q)
        # pre-existing positional indexing still holds (dtype appended)
        dec = [k for k in keys_q if k[1] == "decode"]
        assert dec and all(k[5] is True for k in dec)

    def test_lookahead_engine_token_exact(self):
        cfg = _moe_plan_cfg()
        params = models.init_params(jax.random.PRNGKey(0), cfg)
        _, toks_off = _serve(cfg, params, use_moe_decode=True)
        eng_on, toks_on = _serve(cfg, params, use_moe_decode=True,
                                 router_lookahead=True)
        assert toks_on == toks_off
        assert eng_on.router_lookahead is True

    def test_engine_quantizes_at_load(self):
        cfg = _moe_plan_cfg()
        params = models.init_params(jax.random.PRNGKey(0), cfg)
        from repro.serving import Engine
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     expert_dtype="int8")
        qp = eng.runner.params
        moe_leaf = qp["stack"]["groups"][0]["moe"]
        assert moe_leaf["w1"].dtype == jnp.int8
        assert "w1_scale" in moe_leaf
        # original params untouched
        assert params["stack"]["groups"][0]["moe"]["w1"].dtype != jnp.int8

    def test_engine_validation_errors(self):
        from repro.serving import Engine
        cfg = _moe_plan_cfg()
        params = models.init_params(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="bf16"):
            Engine(cfg, params, expert_dtype="fp8")
        with pytest.raises(ValueError, match="gmm"):
            Engine(cfg.with_(moe_impl="dense"), params, expert_dtype="int8")
        mamba_cfg = get_config("mamba2-780m").reduced()
        with pytest.raises(ValueError, match="mamba"):
            Engine(mamba_cfg, {}, router_lookahead=True,
                   cache_layout="contiguous")
