"""Per-architecture smoke tests on reduced configs (assignment requirement).

Each assigned arch (and the paper's own MoEs) instantiates a REDUCED config of
the same family and runs one forward/train step on CPU, asserting output
shapes and absence of NaNs.  Full configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ASSIGNED, PAPER_MOES, get_config

ALL_ARCHS = ASSIGNED + PAPER_MOES


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_smoke(name, key):
    cfg = get_config(name).reduced()
    params = models.init_params(key, cfg)
    batch = models.make_train_batch(cfg, key, 2, 32)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: models.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    # every parameter receives a finite gradient
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), name
    # at least one grad is nonzero (model is actually wired in)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat), name


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_consistency(name, key):
    """decode(t) after prefill(0..t-1) must match full-forward logits.

    MoE configs run dropless (high capacity factor): equivalence is only
    promised modulo capacity drops, which differ across token counts.
    """
    cfg = get_config(name).reduced()
    if cfg.is_moe:
        cfg = cfg.with_(moe_capacity_factor=float(cfg.num_experts))
    params = models.init_params(key, cfg)
    b, s = 2, 16
    batch = models.make_train_batch(cfg, key, b, s)
    tokens = batch["tokens"]

    caches = models.init_caches(cfg, b, max_len=64)
    pre_batch = {k: v for k, v in batch.items() if k != "targets" and k != "mask"}
    pre_batch["tokens"] = tokens[:, :-1]
    if "frames" in batch:
        pre_batch["frames"] = batch["frames"]
    logits_pre, caches = models.prefill_fn(params, cfg, pre_batch, caches)

    plen = batch.get("prefix_embeds", jnp.zeros((b, 0, 1))).shape[1]
    pos = jnp.full((b,), s - 1 + plen, jnp.int32)
    logits_dec, _ = models.decode_fn(params, cfg, tokens[:, -1], pos, caches)

    # reference: full forward in train mode, take position s-2 (predicting s-1)
    from repro.models import transformer as tf
    from repro.models import encdec as ed
    if cfg.is_encoder_decoder:
        enc = ed.encode(params, cfg, batch["frames"])
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        full, _ = ed._decoder(params, cfg, tokens, positions, "train", None,
                              enc, models.DEFAULT_OPTS)
    else:
        positions = jnp.broadcast_to(jnp.arange(s + plen)[None], (b, s + plen))
        hidden, _, _ = tf.forward(params, cfg, tokens, positions, mode="train",
                                  prefix_embeds=batch.get("prefix_embeds"))
        full = tf.lm_logits(params, cfg, hidden[:, plen:])

    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(full[:, -2]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_lexi_plan_changes_pattern():
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    n = cfg.num_moe_layers
    plan = tuple(1 + (i % cfg.moe_top_k) for i in range(n))
    cfg2 = cfg.with_lexi_plan(plan)
    ks = [b.moe_top_k for b in cfg2.pattern() if b.kind == "attn_moe"]
    assert tuple(ks) == plan


def test_lexi_plan_still_runs():
    key = jax.random.PRNGKey(1)
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    n = cfg.num_moe_layers
    cfg2 = cfg.with_lexi_plan(tuple(1 + (i % 2) for i in range(n)))
    params = models.init_params(key, cfg2)
    batch = models.make_train_batch(cfg2, key, 2, 32)
    loss, _ = models.loss_fn(params, cfg2, batch)
    assert np.isfinite(float(loss))


def test_param_count_sane():
    # full-size analytic counts should be near the models' nameplates
    approx = {
        "olmo-1b": (1.0e9, 1.5e9),
        "qwen3-32b": (30e9, 35e9),
        "qwen3-moe-235b-a22b": (220e9, 245e9),
        "mamba2-780m": (0.7e9, 0.9e9),
    }
    for name, (lo, hi) in approx.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n}"


def test_nonparam_ln_has_no_scale():
    cfg = get_config("olmo-1b").reduced()
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    assert params["final_norm"] == {}


def test_sliding_window_masks_far_tokens():
    """With window W, token attends only to the last W positions."""
    from repro.models.attention import _mask_bias
    q_pos = jnp.array([[10]])
    kv_pos = jnp.arange(12)[None]
    bias = _mask_bias(q_pos, kv_pos, window=4, causal=True)
    visible = np.asarray(bias[0, 0, 0] == 0.0)
    assert visible.tolist() == [False] * 7 + [True] * 4 + [False]
