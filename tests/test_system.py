"""End-to-end system tests: the paper's claims, asserted on the live system.

C1  inter-expert pruning does not reduce per-token MoE work (structural);
C3  LExI beats uniform top-k reduction at the same active-expert budget;
C4  Alg.1 deviation is 0 at k_base and monotone (covered in test_lexi);
    train -> checkpoint -> restore -> serve works as one pipeline;
    the dry-run entry point compiles a production cell in a subprocess.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core import (
    apply_plan_params,
    inter_prune,
    moe_ffn_flops_per_token,
    optimize,
    profile_sensitivity,
)
from repro.data import DataConfig
from repro.optim import AdamW
from repro.training import eval_perplexity, train

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("olmoe-1b-7b").reduced().with_(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        num_experts=8, moe_top_k=4, moe_d_ff=128, vocab_size=512,
        vocab_pad_multiple=16, dtype="float32", moe_capacity_factor=2.0)
    dc = DataConfig(cfg.vocab_size, seq_len=64, global_batch=16, seed=0)
    res = train(cfg, dc, total_steps=150,
                optimizer=AdamW(peak_lr=2e-3, total_steps=150,
                                warmup_steps=10))
    return cfg, res.state.params, dc


class TestPaperClaims:
    def test_c1_inter_pruning_keeps_per_token_work(self, trained):
        """Claim C1 (structural form): removing experts leaves top-k routed
        work per token unchanged -- the throughput non-gain the paper measures."""
        cfg, params, _ = trained
        _, cfg_p = inter_prune(params, cfg, 0.25)
        f0 = moe_ffn_flops_per_token(cfg)
        f1 = moe_ffn_flops_per_token(cfg_p)
        assert f0 == f1

    @pytest.mark.xfail(
        strict=False,
        reason="toy-scale limitation: on the 4-layer smoke model the "
               "Gaussian-input sensitivity table is near-uniform across "
               "layers (~2% spread -- no claim-C2 heterogeneity to exploit), "
               "so the additive proxy cannot reliably beat uniform; the "
               "claim needs depth-heterogeneous sensitivity (paper Fig. 3)")
    def test_c3_lexi_beats_uniform_at_same_budget(self, trained):
        """The headline claim: layer-adaptive allocation >= uniform top-k
        reduction at the same total budget (held-out ppl on trained model).

        Measured on the dropless ``gmm`` path: the paper's reference MoE has
        no capacity concept, and evaluating reduced-k plans under capacity
        buffers conflates allocation quality with capacity-overflow drops
        (cap shrinks with k, so smaller-k plans get punished for drops, not
        for routing width).
        """
        cfg, params, dc = trained
        cfg = cfg.with_(moe_impl="gmm")
        n = cfg.num_moe_layers
        budget = n * cfg.moe_top_k // 2           # 50 % active experts

        plan = optimize(params, cfg, budget, method="dp", n_iter=8,
                        profile_batch=2, profile_seq=32)
        cfg_l, params_l = apply_plan_params(params, cfg, plan)
        ppl_lexi = eval_perplexity(params_l, cfg_l, dc, steps=4)

        cfg_u = cfg.with_lexi_plan((cfg.moe_top_k // 2,) * n)
        ppl_uniform = eval_perplexity(params, cfg_u, dc, steps=4)
        assert ppl_lexi < ppl_uniform, (ppl_lexi, ppl_uniform)

    def test_c3_lexi_within_tolerance_of_uniform(self, trained):
        """Enforced regression guard for the xfail'd strict claim above: a
        DP plan must at least stay in the same quality regime as uniform
        top-k at equal budget (dropless eval; currently ~6% worse on the
        toy model, bound at 15%).  Catches optimizer/profiler regressions
        that would make plans catastrophically bad."""
        cfg, params, dc = trained
        cfg = cfg.with_(moe_impl="gmm")
        n = cfg.num_moe_layers
        budget = n * cfg.moe_top_k // 2
        plan = optimize(params, cfg, budget, method="dp", n_iter=8,
                        profile_batch=2, profile_seq=32)
        cfg_l, params_l = apply_plan_params(params, cfg, plan)
        ppl_lexi = eval_perplexity(params_l, cfg_l, dc, steps=4)
        cfg_u = cfg.with_lexi_plan((cfg.moe_top_k // 2,) * n)
        ppl_uniform = eval_perplexity(params, cfg_u, dc, steps=4)
        assert ppl_lexi <= ppl_uniform * 1.15, (ppl_lexi, ppl_uniform)

    def test_c3_lexi_close_to_baseline(self, trained):
        """At 75% budget the plan should track baseline quality closely
        (dropless eval -- see test_c3_lexi_beats_uniform_at_same_budget)."""
        cfg, params, dc = trained
        cfg = cfg.with_(moe_impl="gmm")
        n = cfg.num_moe_layers
        ppl_base = eval_perplexity(params, cfg, dc, steps=4)
        plan = optimize(params, cfg, int(0.75 * n * cfg.moe_top_k),
                        method="dp", n_iter=8, profile_batch=2,
                        profile_seq=32)
        cfg_l, params_l = apply_plan_params(params, cfg, plan)
        ppl = eval_perplexity(params_l, cfg_l, dc, steps=4)
        assert ppl < ppl_base * 1.35, (ppl, ppl_base)

    def test_plan_reduces_structural_cost(self, trained):
        cfg, params, _ = trained
        n = cfg.num_moe_layers
        plan = optimize(params, cfg, n * cfg.moe_top_k // 2, method="dp",
                        n_iter=4, profile_batch=2, profile_seq=32)
        f_base = moe_ffn_flops_per_token(cfg)
        f_plan = moe_ffn_flops_per_token(cfg, plan.plan)
        assert f_plan == pytest.approx(0.5 * f_base, rel=0.01)


class TestPipelineE2E:
    def test_train_checkpoint_serve(self, trained, tmp_path):
        """train -> checkpoint -> restore -> continuous-batching serve."""
        from repro.checkpoint import CheckpointManager
        from repro.serving import Engine, Request
        cfg, params, _ = trained
        mgr = CheckpointManager(str(tmp_path / "ck"))
        mgr.save(1, {"params": params})
        restored, _ = mgr.restore({"params": params})
        eng = Engine(cfg, restored["params"], max_batch=2, max_len=128,
                     prefill_pad=16)
        rng = np.random.default_rng(0)
        out = eng.serve([
            Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 10
                                               ).astype(np.int32),
                    max_new_tokens=4) for i in range(3)])
        assert [len(r.tokens) for r in out] == [4, 4, 4]

    def test_dryrun_cell_subprocess(self):
        """The production dry-run entry point compiles a real cell."""
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
             "--shape", "decode_32k", "--mesh", "single"],
            capture_output=True, text=True, env=env, timeout=540)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        assert "[OK]" in r.stdout

    def test_benchmark_harness_importable(self):
        import benchmarks.run as br
        assert set(br.BENCHES) >= {"fig2", "fig3", "fig4", "alg2", "roofline"}
