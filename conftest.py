"""Pytest bootstrap: make `repro` (src layout) and `benchmarks` importable
regardless of how pytest is invoked, and keep the suite collectable on
machines without the dev extras (requirements-dev.txt)."""

import inspect
import os
import random
import sys

ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


# --------------------------------------------------------------------------- #
# hypothesis fallback
#
# The property tests use a small slice of hypothesis (@given/@settings with
# integer/float strategies).  When the real package is absent (minimal
# containers), register a deterministic fallback that runs each property on
# the strategy endpoints plus seeded random draws, so the suite still
# collects and the properties still execute.  `pip install -r
# requirements-dev.txt` gets the real shrinking engine.
# --------------------------------------------------------------------------- #

try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    class _Strategy:
        def __init__(self, lo, hi, cast):
            self.lo, self.hi, self.cast = lo, hi, cast

        def draw(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            if self.cast is int:
                return rng.randint(self.lo, self.hi)
            return rng.uniform(self.lo, self.hi)

    def _integers(min_value, max_value):
        return _Strategy(min_value, max_value, int)

    def _floats(min_value, max_value, **_kw):
        return _Strategy(min_value, max_value, float)

    class _BoolStrategy:
        def draw(self, rng, i):
            if i < 2:
                return bool(i)          # endpoints first: False, True
            return rng.random() < 0.5

    def _booleans():
        return _BoolStrategy()

    class _SampledStrategy:
        def __init__(self, elements):
            self.elems = list(elements)

        def draw(self, rng, i):
            if i < len(self.elems):
                return self.elems[i]    # endpoints first: each element once
            return rng.choice(self.elems)

    def _sampled_from(elements):
        return _SampledStrategy(elements)

    def _given(*strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(fn.__qualname__)
                for i in range(n):
                    fn(*args, *(s.draw(rng, i) for s in strats), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            # hide the property args from pytest's fixture resolution: the
            # visible signature keeps only a leading ``self``
            params = list(inspect.signature(fn).parameters.values())
            keep = params[:1] if params and params[0].name == "self" else []
            wrapper.__signature__ = inspect.Signature(keep)
            wrapper._hypothesis_fallback = True
            return wrapper
        return deco

    def _settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _h = types.ModuleType("hypothesis")
    _h.given = _given
    _h.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _h.strategies = _st
    sys.modules["hypothesis"] = _h
    sys.modules["hypothesis.strategies"] = _st
