"""Pytest bootstrap: make `repro` (src layout) and `benchmarks` importable
regardless of how pytest is invoked."""

import os
import sys

ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
